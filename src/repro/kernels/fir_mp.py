"""Pallas kernels: in-filter MP FIR (paper eq. 8 + 9, Fig. 5).

y[b, n] = mpabs(h + x[b, n-M+1..n]) - mpabs(h - x[b, n-M+1..n])

TPU adaptation of the FPGA's register-bank streaming: instead of
materializing the (N, M) sliding-window matrix in HBM (M-fold read
amplification) the raw signal row lives in VMEM and the M tap-shifted views
are formed in-register with static slices (M is a small compile-time
constant, 16 in the paper), unrolled. Both MP bisection states advance
together as in mp_linear.

Optionally fuses the paper's entire in-filter readout
    s[b] = sum_n max(0, y[b, n])        (HWR + accumulate, Appendix A)
so one HBM read of the signal produces the scalar kernel feature directly —
the TPU analogue of the FPGA's per-band accumulator register.

Four kernel families live here, two grid layouts:

* one-shot (``fir_mp_pallas`` / ``fir_mp_bank_pallas``): grid over
  (batch_tile,) or (batch_tile, filter) — the whole signal row is resident
  per step; block holds (block_b, N) rows in VMEM (1 s @ 16 kHz f32 =
  64 KiB/row; block_b=8 -> 0.5 MiB).
* streaming (``fir_mp_stream_octave``): grid (slot_tile, chunk_block,
  filter) — per-slot FIR delay lines, partial accumulators and running
  amax carried in VMEM scratch across the chunk_block axis.

Each has an integer twin (``fir_mp_bank_q_pallas`` /
``fir_mp_stream_octave_q``) executing ``repro.core.fixed``'s bit-true
fixed-point datapath — integer bisection, shift/add/compare only — on the
same grids, bit-for-bit equal to the ``fxp_*`` XLA kernels on either
carrier (int32, or f32-carried integer codes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fixed as fx
from repro.core import mp as mp_mod
from repro.core.filterbank import accumulate_block_len

DEFAULT_ITERS = 26


def _fir_mp_body(x, h_ref, gamma, *, iters: int, M: int):
    """x: (bb, N) raw signal rows — NO upstream left-padding is assumed;
    windows clamp at the left edge by zero-shifting (streaming from zeroed
    registers, as the FPGA does)."""
    bb, N = x.shape

    def shifted(k):
        # x[n-k] with zeros for n < k: shift right by k.
        if k == 0:
            return x
        return jnp.concatenate(
            [jnp.zeros((bb, k), x.dtype), x[:, : N - k]], axis=1)

    xs = [shifted(k) for k in range(M)]  # unrolled; M is static & small

    # per-n bisection bounds
    hi_u = xs[0] * 0.0 - jnp.inf
    hi_v = hi_u
    for k in range(M):
        hk = h_ref[0, k]
        hi_u = jnp.maximum(hi_u, jnp.abs(xs[k] + hk))
        hi_v = jnp.maximum(hi_v, jnp.abs(xs[k] - hk))
    lo_u, lo_v = hi_u - gamma, hi_v - gamma

    def body(_, state):
        lo_u, hi_u, lo_v, hi_v = state
        mid_u = (lo_u + hi_u) * 0.5
        mid_v = (lo_v + hi_v) * 0.5
        hu = jnp.zeros_like(mid_u)
        hv = jnp.zeros_like(mid_v)
        for k in range(M):
            hk = h_ref[0, k]
            u = xs[k] + hk
            v = xs[k] - hk
            hu = hu + jnp.maximum(u - mid_u, 0) + jnp.maximum(-u - mid_u, 0)
            hv = hv + jnp.maximum(v - mid_v, 0) + jnp.maximum(-v - mid_v, 0)
        tu = hu > gamma
        tv = hv > gamma
        lo_u = jnp.where(tu, mid_u, lo_u)
        hi_u = jnp.where(tu, hi_u, mid_u)
        lo_v = jnp.where(tv, mid_v, lo_v)
        hi_v = jnp.where(tv, hi_v, mid_v)
        return lo_u, hi_u, lo_v, hi_v

    lo_u, hi_u, lo_v, hi_v = jax.lax.fori_loop(
        0, iters, body, (lo_u, hi_u, lo_v, hi_v))
    return (lo_u + hi_u) * 0.5 - (lo_v + hi_v) * 0.5


def _fir_mp_kernel(gamma_ref, x_ref, h_ref, out_ref, *, iters, M, accumulate,
                   valid_n):
    y = _fir_mp_body(x_ref[...], h_ref, gamma_ref[0, 0], iters=iters, M=M)
    if accumulate:
        # mask the padded tail: positions >= valid_n see partial windows of
        # real data and would otherwise contribute spurious HWR terms.
        n_idx = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(n_idx < valid_n, y, 0.0)
        out_ref[...] = jnp.sum(jnp.maximum(y, 0.0), axis=-1, keepdims=True)
    else:
        out_ref[...] = y


def fir_mp_bank_pallas(
    x: jax.Array,
    H: jax.Array,
    gamma: jax.Array,
    *,
    accumulate: bool = False,
    iters: int = DEFAULT_ITERS,
    block_b: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Multi-filter variant: x (B, N), H (F, M) -> (F, B, N) or (B, F).

    Grid covers (batch_tile, filter) with the filter axis innermost, so the
    (block_b, N) signal block's index map is constant across the F inner
    steps: Pallas keeps it VMEM-resident and only the (1, M) tap row is
    re-fetched per filter. The per-filter path re-reads the signal from HBM
    F times; here one read serves the whole octave.
    """
    B, N = x.shape
    F, M = H.shape
    b_pad = (-B) % block_b
    n_pad = (-N) % 128
    xp = jnp.pad(x, ((0, b_pad), (0, n_pad)))
    Bp, Np = xp.shape
    H = H.astype(x.dtype)
    gamma_arr = jnp.asarray(gamma, dtype=x.dtype).reshape(1, 1)

    if accumulate:
        out_spec = pl.BlockSpec((block_b, 1), lambda i, j: (i, j))
        out_shape = jax.ShapeDtypeStruct((Bp, F), x.dtype)
    else:
        out_spec = pl.BlockSpec((1, block_b, Np), lambda i, j: (j, i, 0))
        out_shape = jax.ShapeDtypeStruct((F, Bp, Np), x.dtype)

    out = pl.pallas_call(
        functools.partial(_fir_mp_bank_kernel, iters=iters, M=M,
                          accumulate=accumulate, valid_n=N),
        grid=(Bp // block_b, F),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_b, Np), lambda i, j: (i, 0)),
            pl.BlockSpec((1, M), lambda i, j: (j, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(gamma_arr, xp, H)

    if accumulate:
        return out[:B, :]
    return out[:, :B, :N]


def _fir_mp_bank_kernel(gamma_ref, x_ref, h_ref, out_ref, *, iters, M,
                        accumulate, valid_n):
    y = _fir_mp_body(x_ref[...], h_ref, gamma_ref[0, 0], iters=iters, M=M)
    if accumulate:
        n_idx = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(n_idx < valid_n, y, 0.0)
        out_ref[...] = jnp.sum(jnp.maximum(y, 0.0), axis=-1, keepdims=True)
    else:
        out_ref[...] = y[None]


# ---------------------------------------------------------------------------
# fir_mp_stream: stateful session-step kernel
# ---------------------------------------------------------------------------


def _fir_mp_stream_kernel(gamma_ref, x_ref, n_ref, start_ref, delay_ref,
                          acc_ref, amax_ref, h_ref, lp_ref, *refs,
                          solver, scale, emit_next, update_amax,
                          T1, M, M_lp, LB):
    """One grid step of the streaming octave kernel.

    Grid is (slot_block, chunk_block, filter) with filter INNERMOST: the
    (bs, LB) signal block's index map is constant across the F filter steps,
    so Pallas keeps it VMEM-resident and only the (1, M) tap row is
    re-fetched per filter (same trick as fir_mp_bank). The slot state —
    FIR delay line, per-band partial accumulators, running amax — lives in
    VMEM scratch and is carried across the chunk_block axis: the chunk
    streams through VMEM block by block with NO per-block HBM state
    round-trip; state is read once at grid start and written once at the
    final step.

    Bit-parity with the XLA session step is by construction: the same
    ``mp._mp_dot_fast`` solver runs on the same window values (per-row
    minor-axis reductions are leading-shape independent), and the HWR sums
    use the shared ``accumulate_block_len`` blocking, added in ascending
    block order exactly like ``filterbank.hwr_accumulate``.
    """
    if emit_next:
        out_acc_ref, out_delay_ref, out_amax_ref, out_next_ref = refs[:4]
        delay_s, part_s, amax_s = refs[4:]
    else:
        out_acc_ref, out_delay_ref, out_amax_ref = refs[:3]
        delay_s, part_s, amax_s = refs[3:]

    b = pl.program_id(1)
    f = pl.program_id(2)
    NB = pl.num_programs(1)
    F = pl.num_programs(2)

    @pl.when((b == 0) & (f == 0))
    def _init():
        delay_s[...] = delay_ref[...]
        part_s[...] = jnp.zeros_like(part_s)
        amax_s[...] = amax_ref[...]

    blk = x_ref[...]                              # (bs, LB)
    nv = n_ref[...][:, 0]                         # (bs,) valid counts
    gamma = gamma_ref[0, 0]

    if update_amax:
        # running amax: invalid tails were zeroed upstream, and the padded
        # tail block is zeros, so blockwise max == whole-row max (max is
        # exactly associative; all operands >= +0.0).
        @pl.when(f == 0)
        def _amax():
            amax_s[...] = jnp.maximum(
                amax_s[...],
                jnp.max(jnp.abs(blk), axis=-1, keepdims=True))

    # --- band-pass filter f over this block -------------------------------
    hist = delay_s[:, T1 - (M - 1):] if M > 1 else delay_s[:, T1:]
    bufv = jnp.concatenate([hist, blk], axis=1)   # (bs, M-1+LB)
    idx = (jax.lax.broadcasted_iota(jnp.int32, (LB, M), 0)
           + jax.lax.broadcasted_iota(jnp.int32, (LB, M), 1))
    win = bufv[:, idx]                            # (bs, LB, M) windows
    h = h_ref[...][0, ::-1]                       # conv tap order, as in XLA
    y = mp_mod._mp_dot_fast(win, h, gamma, solver)
    pos = b * LB + jax.lax.broadcasted_iota(jnp.int32, (1, LB), 1)
    hwr = jnp.where(pos < nv[:, None], jnp.maximum(y, 0.0), 0.0)
    part_s[pl.ds(f, 1), :] = (part_s[pl.ds(f, 1), :]
                              + mp_mod.tree_sum(hwr)[None, :])

    @pl.when(f == F - 1)
    def _block_tail():
        # LP + ÷2 decimation for the next octave: solve ONLY the kept
        # positions. LB is even, so each slot's keep-parity (its decimator
        # phase) is constant across blocks; kept j of block b lands at
        # out position b*LB/2 + j.
        if emit_next:
            histl = (delay_s[:, T1 - (M_lp - 1):] if M_lp > 1
                     else delay_s[:, T1:])
            bufl = jnp.concatenate([histl, blk], axis=1)
            widx = (2 * jax.lax.broadcasted_iota(jnp.int32, (LB // 2, M_lp), 0)
                    + jax.lax.broadcasted_iota(jnp.int32, (LB // 2, M_lp), 1))
            stv = start_ref[...][:, 0]            # per-slot phase in {0, 1}
            winl = jax.vmap(lambda r, s: r[s + widx])(bufl, stv)
            lp = lp_ref[...][0, ::-1]
            out_next_ref[...] = mp_mod._mp_dot_fast(winl, lp, gamma, solver)
        # slide the delay line by this block's VALID sample count; a
        # zero-valid (masked/inert) slot slides by 0 and keeps its
        # registers bit-identical.
        v = jnp.clip(nv - b * LB, 0, LB)
        bufd = jnp.concatenate([delay_s[...], blk], axis=1)
        delay_s[...] = jax.vmap(
            lambda r, s: jax.lax.dynamic_slice(r, (s,), (T1,)))(bufd, v)

    @pl.when((b == NB - 1) & (f == F - 1))
    def _flush():
        out_acc_ref[...] = acc_ref[...] + part_s[...].T * scale
        out_delay_ref[...] = delay_s[...]
        out_amax_ref[...] = amax_s[...]


def fir_mp_stream_octave(
    x: jax.Array,
    n: jax.Array,
    start: jax.Array,
    delay: jax.Array,
    acc: jax.Array,
    amax: jax.Array,
    H: jax.Array,
    lp: jax.Array,
    gamma: jax.Array,
    *,
    scale: float = 1.0,
    solver: str = "newton",
    emit_next: bool = True,
    update_amax: bool = False,
    block_s: int = 8,
    interpret: bool = False,
):
    """One octave of the stateful streaming step, as a single pallas_call.

    x (S, L): this octave's chunk (invalid tails already zeroed/masked
    upstream); n (S,): per-slot valid counts; start (S,): per-slot decimator
    phase (consumed % 2); delay (S, T1): FIR delay line registers; acc
    (S, F): this octave's accumulator columns; amax (S,): running amax
    (updated in-kernel only when ``update_amax``); H (F, M): band-pass taps;
    lp (M_lp,): anti-aliasing taps (ignored unless ``emit_next``).

    Returns ``(acc', delay', amax', y_next | None)`` where ``y_next`` is
    (S, ceil(L/LB) * LB//2) — slice to ``(L+1)//2`` for the next octave.
    """
    S, L = x.shape
    F, M = H.shape
    T1 = delay.shape[1]
    (M_lp,) = lp.shape
    LB = accumulate_block_len(L)
    NB = -(-L // LB)
    bs = min(block_s, S)
    s_pad = (-S) % bs
    Sp = S + s_pad
    dt = x.dtype

    xp = jnp.pad(x, ((0, s_pad), (0, NB * LB - L)))
    pad1 = lambda a: jnp.pad(a, ((0, s_pad),))
    n2 = pad1(n.astype(jnp.int32))[:, None]
    start2 = pad1(start.astype(jnp.int32))[:, None]
    delay_p = jnp.pad(delay, ((0, s_pad), (0, 0)))
    acc_p = jnp.pad(acc, ((0, s_pad), (0, 0)))
    amax2 = pad1(amax.astype(dt))[:, None]
    H = H.astype(dt)
    lp2 = lp.astype(dt)[None, :]
    gamma_arr = jnp.asarray(gamma, dtype=dt).reshape(1, 1)

    out_shape = [
        jax.ShapeDtypeStruct((Sp, F), dt),             # acc'
        jax.ShapeDtypeStruct((Sp, T1), dt),            # delay'
        jax.ShapeDtypeStruct((Sp, 1), dt),             # amax'
    ]
    out_specs = [
        pl.BlockSpec((bs, F), lambda i, b, f: (i, 0)),
        pl.BlockSpec((bs, T1), lambda i, b, f: (i, 0)),
        pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),
    ]
    if emit_next:
        out_shape.append(jax.ShapeDtypeStruct((Sp, NB * (LB // 2)), dt))
        out_specs.append(pl.BlockSpec((bs, LB // 2), lambda i, b, f: (i, b)))

    outs = pl.pallas_call(
        functools.partial(_fir_mp_stream_kernel, solver=solver, scale=scale,
                          emit_next=emit_next, update_amax=update_amax,
                          T1=T1, M=M, M_lp=M_lp, LB=LB),
        grid=(Sp // bs, NB, F),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, b, f: (0, 0)),     # gamma
            pl.BlockSpec((bs, LB), lambda i, b, f: (i, b)),   # signal
            pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),    # valid counts
            pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),    # decim phase
            pl.BlockSpec((bs, T1), lambda i, b, f: (i, 0)),   # delay line
            pl.BlockSpec((bs, F), lambda i, b, f: (i, 0)),    # accumulators
            pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),    # running amax
            pl.BlockSpec((1, M), lambda i, b, f: (f, 0)),     # BP tap row
            pl.BlockSpec((1, M_lp), lambda i, b, f: (0, 0)),  # LP taps
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bs, T1), dt),    # delay line, carried across blocks
            pltpu.VMEM((F, bs), dt),     # per-band partial accumulators
            pltpu.VMEM((bs, 1), dt),     # running amax
        ],
        # scratch is carried across grid steps -> every axis must iterate
        # sequentially on TPU (no parallel partitioning of the grid)
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(gamma_arr, xp, n2, start2, delay_p, acc_p, amax2, H, lp2)

    acc_o = outs[0][:S]
    delay_o = outs[1][:S]
    amax_o = outs[2][:S, 0]
    y_next = outs[3][:S] if emit_next else None
    return acc_o, delay_o, amax_o, y_next


def fir_mp_pallas(
    x: jax.Array,
    h: jax.Array,
    gamma: jax.Array,
    *,
    accumulate: bool = False,
    iters: int = DEFAULT_ITERS,
    block_b: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """x: (B, N) signal, h: (M,) taps -> y: (B, N), or s: (B,) if accumulate.

    The kernel pairs x-shift k with tap h(k) directly, implementing eq. 8's
    sum_k h(k) x(n-k) operand multiset without reordering the taps.
    """
    B, N = x.shape
    (M,) = h.shape
    b_pad = (-B) % block_b
    n_pad = (-N) % 128
    xp = jnp.pad(x, ((0, b_pad), (0, n_pad)))
    Bp, Np = xp.shape
    h_row = h.reshape(1, M).astype(x.dtype)
    gamma_arr = jnp.asarray(gamma, dtype=x.dtype).reshape(1, 1)

    if accumulate:
        out_spec = pl.BlockSpec((block_b, 1), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((Bp, 1), x.dtype)
    else:
        out_spec = pl.BlockSpec((block_b, Np), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((Bp, Np), x.dtype)

    out = pl.pallas_call(
        functools.partial(_fir_mp_kernel, iters=iters, M=M,
                          accumulate=accumulate, valid_n=N),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_b, Np), lambda i: (i, 0)),
            pl.BlockSpec((1, M), lambda i: (0, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(gamma_arr, xp, h_row)

    if accumulate:
        return out[:B, 0]
    return out[:B, :N]


# ---------------------------------------------------------------------------
# integer (fixed-point) kernels: the bit-true hardware twin, VMEM-resident
# ---------------------------------------------------------------------------
#
# Both kernels below run repro.core.fixed's datapath INSIDE the pallas_call:
# integer bisection (arithmetic-shift midpoints, exact integer constraint
# sums), saturating clamps onto static spec bounds, and integer HWR
# accumulation. They are carrier-generic like every fxp_* kernel: on int32
# they are the hardware path (benchmarks/hardware_cost.py censuses the
# Pallas-lowered jaxpr to zero multiplies/divides); on f32-carried integer
# codes they are the fake-quant twin, bit-identical below 2**24.
#
# Parity with the XLA fxp_* kernels is by construction: every output value
# is one LSB-deterministic bisection over the SAME operand multiset
# {h_k +- x(n-k)} (integer max and adds are order-independent), so the
# Pallas and XLA paths agree bit-for-bit — no blocked-reduction ordering
# machinery needed (the float kernels' tree_sum/accumulate_block_len dance
# exists only because float addition is not associative).


def _fxp_mpabs_ops(ops, gamma_q, iters: int):
    """fixed.fxp_mpabs over an unrolled operand list (each (bb, N)): the
    per-position integer bisection, shift/add/compare only."""
    g = fx._c(gamma_q, ops[0])
    hi = jnp.abs(ops[0])
    for t in ops[1:]:
        hi = jnp.maximum(hi, jnp.abs(t))
    lo = hi - g

    def body(_, state):
        lo, hi = state
        mid = fx.shift_right(lo + hi, 1)
        h = jnp.zeros_like(mid)
        for t in ops:
            h = h + fx._relu(t - mid) + fx._relu(-t - mid)
        too_low = h > g
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return hi


def _fxp_fir_mp_body(x, h_ref, *, gamma_q, iters, qmin, qmax, M):
    """Integer twin of ``_fir_mp_body``: x (bb, N) signal codes (already on
    the stage's internal grid), h_ref (1, M) tap codes. Pairs x-shift k with
    tap h(k), forming the same operand multiset as ``fixed.fxp_fir_bank``'s
    reversed-tap windows; operand sums saturate onto [qmin, qmax] (the
    10-bit internal path) before the solve, exactly like ``fxp_mp_dot``."""
    bb, N = x.shape

    def shifted(k):
        if k == 0:
            return x
        return jnp.concatenate(
            [jnp.zeros((bb, k), x.dtype), x[:, : N - k]], axis=1)

    us, vs = [], []
    for k in range(M):
        hk = h_ref[0, k]
        xk = shifted(k)
        us.append(jnp.clip(hk + xk, qmin, qmax))
        vs.append(jnp.clip(hk - xk, qmin, qmax))
    return (_fxp_mpabs_ops(us, gamma_q, iters)
            - _fxp_mpabs_ops(vs, gamma_q, iters))


def _fir_mp_bank_q_kernel(x_ref, h_ref, out_ref, *, gamma_q, iters, qmin,
                          qmax, M, accumulate, valid_n):
    y = _fxp_fir_mp_body(x_ref[...], h_ref, gamma_q=gamma_q, iters=iters,
                         qmin=qmin, qmax=qmax, M=M)
    if accumulate:
        # integer HWR + accumulate: mask the padded tail (positions >=
        # valid_n see partial windows of real data), then a plain sum —
        # integer adds are associative, any order reproduces the XLA bits
        n_idx = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(n_idx < valid_n, fx._relu(y), 0)
        out_ref[...] = jnp.sum(y, axis=-1, keepdims=True)
    else:
        out_ref[...] = y[None]


def fir_mp_bank_q_pallas(
    xq: jax.Array,
    H_q: jax.Array,
    *,
    gamma_q: int,
    iters: int,
    qmin: int,
    qmax: int,
    accumulate: bool = False,
    block_b: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """One-shot integer bank kernel: xq (B, N) signal codes already on the
    stage's internal grid, H_q (F, M) tap codes -> (F, B, N) band codes, or
    (B, F) integer HWR sums (at the stage grid — the caller applies
    ``acc_shift``).

    Same grid as the float ``fir_mp_bank_pallas``: (batch_tile, filter)
    with filter INNERMOST, so the (block_b, N) signal block stays
    VMEM-resident across the whole octave's filter set and only the (1, M)
    tap row re-fetches per filter. ``gamma_q``/``iters``/``qmin``/``qmax``
    are STATIC program constants (ROM contents), not kernel operands.
    Output positions match ``fixed.fxp_fir_bank(pad=True)`` bit-for-bit.
    """
    B, N = xq.shape
    F, M = H_q.shape
    b_pad = (-B) % block_b
    n_pad = (-N) % 128
    xp = jnp.pad(xq, ((0, b_pad), (0, n_pad)))
    Bp, Np = xp.shape
    H_q = H_q.astype(xq.dtype)

    if accumulate:
        out_spec = pl.BlockSpec((block_b, 1), lambda i, j: (i, j))
        out_shape = jax.ShapeDtypeStruct((Bp, F), xq.dtype)
    else:
        out_spec = pl.BlockSpec((1, block_b, Np), lambda i, j: (j, i, 0))
        out_shape = jax.ShapeDtypeStruct((F, Bp, Np), xq.dtype)

    out = pl.pallas_call(
        functools.partial(_fir_mp_bank_q_kernel, gamma_q=int(gamma_q),
                          iters=int(iters), qmin=int(qmin), qmax=int(qmax),
                          M=M, accumulate=accumulate, valid_n=N),
        grid=(Bp // block_b, F),
        in_specs=[
            pl.BlockSpec((block_b, Np), lambda i, j: (i, 0)),
            pl.BlockSpec((1, M), lambda i, j: (j, 0)),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(xp, H_q)

    if accumulate:
        return out[:B, :]
    return out[:, :B, :N]


def _fir_mp_stream_q_kernel(x_ref, n_ref, start_ref, delay_ref, acc_ref,
                            amax_ref, h_ref, lp_ref, *refs,
                            stage, next_qmin, next_qmax, emit_next,
                            update_amax, T1, M, M_lp, LB):
    """One grid step of the INTEGER streaming octave kernel.

    Same grid and VMEM-scratch state machine as ``_fir_mp_stream_kernel``
    — (slot_block, chunk_block, filter), filter innermost, delay line /
    per-band partial accumulators / running amax carried in scratch across
    the chunk_block axis — but every op is the fixed-point datapath:

    * window codes rescale onto the band grid by ``stage.sig_shift``
      (a static shift), operand sums clamp onto the 10-bit internal specs,
      and each position solves by integer bisection
      (``fixed.fxp_mp_dot``) — LSB-deterministic, so no float-style
      reduction-order bookkeeping is needed anywhere;
    * the flush applies ``stage.acc_shift`` as a left shift (the int mirror
      of the float kernel's ``* 2**octave`` renorm — shifts distribute over
      the partial sums, so flush-time shifting equals the XLA session
      step's per-chunk shift bit-for-bit);
    * the decimator tail emits NEXT-OCTAVE register codes directly:
      ``clamp(rescale(kept, lp_out_shift))`` onto [next_qmin, next_qmax]
      happens in-kernel, so y_next needs no post-processing.

    All gammas/iters/shifts/clamp bounds come from the compiled
    ``fixed.OctaveStage`` — static ROM constants, never kernel operands.
    """
    if emit_next:
        out_acc_ref, out_delay_ref, out_amax_ref, out_next_ref = refs[:4]
        delay_s, part_s, amax_s = refs[4:]
    else:
        out_acc_ref, out_delay_ref, out_amax_ref = refs[:3]
        delay_s, part_s, amax_s = refs[3:]

    b = pl.program_id(1)
    f = pl.program_id(2)
    NB = pl.num_programs(1)
    F = pl.num_programs(2)

    @pl.when((b == 0) & (f == 0))
    def _init():
        delay_s[...] = delay_ref[...]
        part_s[...] = jnp.zeros_like(part_s)
        amax_s[...] = amax_ref[...]

    blk = x_ref[...]                              # (bs, LB) register codes
    nv = n_ref[...][:, 0]                         # (bs,) valid counts

    if update_amax:
        # running max |code| telemetry (octave 0): invalid tails are zero
        # codes and never raise the max — integer max is associative, so
        # blockwise max == whole-chunk max
        @pl.when(f == 0)
        def _amax():
            amax_s[...] = jnp.maximum(
                amax_s[...],
                jnp.max(jnp.abs(blk), axis=-1, keepdims=True))

    # --- band-pass filter f over this block (integer MP solve) ------------
    hist = delay_s[:, T1 - (M - 1):] if M > 1 else delay_s[:, T1:]
    bufv = jnp.concatenate([hist, blk], axis=1)   # (bs, M-1+LB)
    idx = (jax.lax.broadcasted_iota(jnp.int32, (LB, M), 0)
           + jax.lax.broadcasted_iota(jnp.int32, (LB, M), 1))
    win = fx.rescale(bufv[:, idx], stage.sig_shift)    # onto the band grid
    h = h_ref[...][0, ::-1]                       # conv tap order, as in XLA
    y = fx.fxp_mp_dot(win, h, stage.gamma_bp, stage.iters_bp,
                      stage.band_spec)
    pos = b * LB + jax.lax.broadcasted_iota(jnp.int32, (1, LB), 1)
    hwr = jnp.where(pos < nv[:, None], fx._relu(y), 0)
    part_s[pl.ds(f, 1), :] = (part_s[pl.ds(f, 1), :]
                              + jnp.sum(hwr, axis=-1)[None, :])

    @pl.when(f == F - 1)
    def _block_tail():
        # LP + ÷2 decimation: solve ONLY the kept positions (LB is even, so
        # each slot's keep-parity is constant across blocks; kept j of
        # block b lands at out position b*LB/2 + j), then requantize onto
        # the next octave's register grid in-kernel.
        if emit_next:
            histl = (delay_s[:, T1 - (M_lp - 1):] if M_lp > 1
                     else delay_s[:, T1:])
            bufl = jnp.concatenate([histl, blk], axis=1)
            widx = (2 * jax.lax.broadcasted_iota(jnp.int32, (LB // 2, M_lp), 0)
                    + jax.lax.broadcasted_iota(jnp.int32, (LB // 2, M_lp), 1))
            stv = start_ref[...][:, 0]            # per-slot phase in {0, 1}
            winl = fx.rescale(
                jax.vmap(lambda r, s: r[s + widx])(bufl, stv),
                stage.lp_sig_shift)
            lp = lp_ref[...][0, ::-1]
            kept = fx.fxp_mp_dot(winl, lp, stage.gamma_lp, stage.iters_lp,
                                 stage.lp_spec)
            out_next_ref[...] = jnp.clip(
                fx.rescale(kept, stage.lp_out_shift), next_qmin, next_qmax)
        # slide the delay line by this block's VALID sample count; a
        # zero-valid (masked/inert) slot slides by 0 and keeps its
        # registers bit-identical.
        v = jnp.clip(nv - b * LB, 0, LB)
        bufd = jnp.concatenate([delay_s[...], blk], axis=1)
        delay_s[...] = jax.vmap(
            lambda r, s: jax.lax.dynamic_slice(r, (s,), (T1,)))(bufd, v)

    @pl.when((b == NB - 1) & (f == F - 1))
    def _flush():
        out_acc_ref[...] = acc_ref[...] + fx.shift_left(part_s[...].T,
                                                        stage.acc_shift)
        out_delay_ref[...] = delay_s[...]
        out_amax_ref[...] = amax_s[...]


def fir_mp_stream_octave_q(
    x: jax.Array,
    n: jax.Array,
    start: jax.Array,
    delay: jax.Array,
    acc: jax.Array,
    amax: jax.Array,
    *,
    stage,
    next_spec=None,
    emit_next: bool = True,
    update_amax: bool = False,
    block_s: int = 8,
    interpret: bool = False,
):
    """One octave of the INTEGER streaming step, as a single pallas_call.

    x (S, L): this octave's chunk of register codes (invalid tails already
    zeroed upstream); n (S,): per-slot valid counts; start (S,): per-slot
    decimator phase (``consumed & 1``); delay (S, T1): delay-line register
    codes; acc (S, F): 32-bit accumulator columns; amax (S,): running max
    |code| (updated in-kernel only when ``update_amax`` — octave 0).
    ``stage`` is the compiled :class:`repro.core.fixed.OctaveStage` (taps,
    gammas, iteration counts, shifts and clamp bounds — all static);
    ``next_spec`` the NEXT octave's register spec (required with
    ``emit_next``).

    Returns ``(acc', delay', amax', y_next | None)`` where ``y_next`` is
    (S, ceil(L/LB) * LB//2) next-octave register codes — slice to
    ``(L+1)//2``. Carrier-generic: int32 or f32-carried codes.
    """
    S, L = x.shape
    F, M = stage.bp_q.shape
    T1 = delay.shape[1]
    LB = accumulate_block_len(L)
    NB = -(-L // LB)
    bs = min(block_s, S)
    s_pad = (-S) % bs
    Sp = S + s_pad
    dt = x.dtype

    if emit_next:
        lp2 = stage.lp_q.astype(dt)              # (1, M_lp)
        next_qmin, next_qmax = int(next_spec.qmin), int(next_spec.qmax)
    else:
        lp2 = jnp.zeros((1, 1), dt)
        next_qmin = next_qmax = 0
    (_, M_lp) = lp2.shape

    xp = jnp.pad(x, ((0, s_pad), (0, NB * LB - L)))
    pad1 = lambda a: jnp.pad(a, ((0, s_pad),))
    n2 = pad1(n.astype(jnp.int32))[:, None]
    start2 = pad1(start.astype(jnp.int32))[:, None]
    delay_p = jnp.pad(delay, ((0, s_pad), (0, 0)))
    acc_p = jnp.pad(acc, ((0, s_pad), (0, 0)))
    amax2 = pad1(amax.astype(dt))[:, None]
    H = stage.bp_q.astype(dt)

    out_shape = [
        jax.ShapeDtypeStruct((Sp, F), dt),             # acc'
        jax.ShapeDtypeStruct((Sp, T1), dt),            # delay'
        jax.ShapeDtypeStruct((Sp, 1), dt),             # amax'
    ]
    out_specs = [
        pl.BlockSpec((bs, F), lambda i, b, f: (i, 0)),
        pl.BlockSpec((bs, T1), lambda i, b, f: (i, 0)),
        pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),
    ]
    if emit_next:
        out_shape.append(jax.ShapeDtypeStruct((Sp, NB * (LB // 2)), dt))
        out_specs.append(pl.BlockSpec((bs, LB // 2), lambda i, b, f: (i, b)))

    outs = pl.pallas_call(
        functools.partial(_fir_mp_stream_q_kernel, stage=stage,
                          next_qmin=next_qmin, next_qmax=next_qmax,
                          emit_next=emit_next, update_amax=update_amax,
                          T1=T1, M=M, M_lp=M_lp, LB=LB),
        grid=(Sp // bs, NB, F),
        in_specs=[
            pl.BlockSpec((bs, LB), lambda i, b, f: (i, b)),   # signal codes
            pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),    # valid counts
            pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),    # decim phase
            pl.BlockSpec((bs, T1), lambda i, b, f: (i, 0)),   # delay line
            pl.BlockSpec((bs, F), lambda i, b, f: (i, 0)),    # accumulators
            pl.BlockSpec((bs, 1), lambda i, b, f: (i, 0)),    # running amax
            pl.BlockSpec((1, M), lambda i, b, f: (f, 0)),     # BP tap row
            pl.BlockSpec((1, M_lp), lambda i, b, f: (0, 0)),  # LP taps
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bs, T1), dt),    # delay line, carried across blocks
            pltpu.VMEM((F, bs), dt),     # per-band partial accumulators
            pltpu.VMEM((bs, 1), dt),     # running amax
        ],
        # scratch is carried across grid steps -> every axis must iterate
        # sequentially on TPU (no parallel partitioning of the grid)
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(xp, n2, start2, delay_p, acc_p, amax2, H, lp2)

    acc_o = outs[0][:S]
    delay_o = outs[1][:S]
    amax_o = outs[2][:S, 0]
    y_next = outs[3][:S] if emit_next else None
    return acc_o, delay_o, amax_o, y_next
