"""Pallas kernel: fused multiplierless MP matrix product (paper eq. 9).

y[b, o] = mpabs(w[:, o] + x[b, :], gamma) - mpabs(w[:, o] - x[b, :], gamma)
with mpabs(u, g) = MP([u; -u], g).

Fusion: both bisection states (u and v) advance in the same loop, so x and w
tiles are read from VMEM once per iteration instead of running two separate
MP solves (2x traffic) or materializing the (b, o, 2d) operand tensor in HBM
(the naive port of eq. 9).

Tiling: grid (B/block_b, O/block_o). Per step the block holds
x (block_b, d) + w (d, block_o) in VMEM and streams the d axis in chunks of
`chunk_d` inside the bisection loop, so VMEM stays bounded for large d:
  footprint ~ block_b*d + d*block_o + 4 * block_b*block_o  (+ chunk scratch)
with block_b=8, block_o=128, d=4096, f32: 128K + 2M + 16K ~= 2.2 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ITERS = 26


def _mp_linear_kernel(gamma_ref, x_ref, w_ref, out_ref, *, iters: int,
                      chunk_d: int):
    x = x_ref[...]          # (bb, d)
    w = w_ref[...]          # (d, bo)
    gamma = gamma_ref[0, 0]
    bb, d = x.shape
    bo = w.shape[1]
    n_chunks = d // chunk_d

    def chunked(f, init):
        def body(c, acc):
            xs = jax.lax.dynamic_slice_in_dim(x, c * chunk_d, chunk_d, 1)
            ws = jax.lax.dynamic_slice_in_dim(w, c * chunk_d, chunk_d, 0)
            return f(acc, xs, ws)
        return jax.lax.fori_loop(0, n_chunks, body, init)

    # init: hi_u = max_d |x + w|, hi_v = max_d |x - w|  per (b, o)
    def amax_step(acc, xs, ws):
        au, av = acc
        u = xs[:, None, :] + ws.T[None, :, :]     # (bb, bo, chunk)
        v = xs[:, None, :] - ws.T[None, :, :]
        au = jnp.maximum(au, jnp.max(jnp.abs(u), -1))
        av = jnp.maximum(av, jnp.max(jnp.abs(v), -1))
        return au, av

    zeros = jnp.zeros((bb, bo), x.dtype)
    hi_u, hi_v = chunked(amax_step, (zeros, zeros))
    lo_u, lo_v = hi_u - gamma, hi_v - gamma

    def bisect_body(_, state):
        lo_u, hi_u, lo_v, hi_v = state
        mid_u = (lo_u + hi_u) * 0.5
        mid_v = (lo_v + hi_v) * 0.5

        def hinge_step(acc, xs, ws):
            hu, hv = acc
            u = xs[:, None, :] + ws.T[None, :, :]
            v = xs[:, None, :] - ws.T[None, :, :]
            hu = hu + (jnp.sum(jnp.maximum(u - mid_u[..., None], 0), -1)
                       + jnp.sum(jnp.maximum(-u - mid_u[..., None], 0), -1))
            hv = hv + (jnp.sum(jnp.maximum(v - mid_v[..., None], 0), -1)
                       + jnp.sum(jnp.maximum(-v - mid_v[..., None], 0), -1))
            return hu, hv

        hu, hv = chunked(hinge_step, (zeros, zeros))
        tu = hu > gamma
        tv = hv > gamma
        lo_u = jnp.where(tu, mid_u, lo_u)
        hi_u = jnp.where(tu, hi_u, mid_u)
        lo_v = jnp.where(tv, mid_v, lo_v)
        hi_v = jnp.where(tv, hi_v, mid_v)
        return lo_u, hi_u, lo_v, hi_v

    lo_u, hi_u, lo_v, hi_v = jax.lax.fori_loop(
        0, iters, bisect_body, (lo_u, hi_u, lo_v, hi_v))
    z_u = (lo_u + hi_u) * 0.5
    z_v = (lo_v + hi_v) * 0.5
    out_ref[...] = z_u - z_v


def mp_linear_pallas(
    x: jax.Array,
    w: jax.Array,
    gamma: jax.Array,
    *,
    iters: int = DEFAULT_ITERS,
    block_b: int = 8,
    block_o: int = 128,
    chunk_d: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """x: (B, d), w: (d, O), gamma scalar -> y: (B, O)."""
    B, d = x.shape
    d2, O = w.shape
    assert d == d2
    chunk_d = min(chunk_d, d)
    assert d % chunk_d == 0, (
        f"d={d} must be a multiple of chunk_d={chunk_d}; the reduction axis "
        "cannot be zero-padded (padding would perturb the water-filling)")
    b_pad = (-B) % block_b
    o_pad = (-O) % block_o
    # Batch rows pad with zeros (harmless: extra rows are discarded); output
    # columns pad with zero weights (extra outputs discarded).
    xp = jnp.pad(x, ((0, b_pad), (0, 0)))
    wp = jnp.pad(w, ((0, 0), (0, o_pad)))
    Bp, Op = xp.shape[0], wp.shape[1]
    gamma_arr = jnp.asarray(gamma, dtype=x.dtype).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_mp_linear_kernel, iters=iters, chunk_d=chunk_d),
        grid=(Bp // block_b, Op // block_o),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, block_o), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), x.dtype),
        interpret=interpret,
    )(gamma_arr, xp, wp)
    return out[:B, :O]
