"""Pure-jnp oracles for the Pallas kernels (tests assert against these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mp as mp_mod


def mp_waterfill_ref(L: jax.Array, gamma) -> jax.Array:
    """Exact sort-based reverse water-filling; the bisection kernel must
    converge to this within interval/2^iters."""
    return mp_mod.mp_exact(L, gamma)


def mp_linear_ref(x: jax.Array, w: jax.Array, gamma) -> jax.Array:
    """(B, d) @ (d, O) in the MP domain via the exact solver."""
    return mp_mod.mp_linear(x, w, gamma, exact=True)


def fir_mp_ref(x: jax.Array, h: jax.Array, gamma) -> jax.Array:
    """Windowed exact MP FIR, same zero initial state as the kernel."""
    return mp_mod.mp_conv1d(x, h, gamma, exact=True)


def fir_mp_accumulate_ref(x: jax.Array, h: jax.Array, gamma) -> jax.Array:
    y = fir_mp_ref(x, h, gamma)
    return jnp.sum(jnp.maximum(y, 0.0), axis=-1)


def fir_mp_bank_ref(x: jax.Array, H: jax.Array, gamma) -> jax.Array:
    """Per-band exact MP FIR stacked to (..., F, N): the fir_mp_bank oracle
    is literally F independent single-filter solves."""
    return jnp.stack([fir_mp_ref(x, H[f], gamma)
                      for f in range(H.shape[0])], axis=-2)


def fir_mp_bank_accumulate_ref(x: jax.Array, H: jax.Array, gamma) -> jax.Array:
    y = fir_mp_bank_ref(x, H, gamma)
    return jnp.sum(jnp.maximum(y, 0.0), axis=-1)
