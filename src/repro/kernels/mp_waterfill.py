"""Pallas kernel: row-wise reverse water-filling z = MP(L, gamma).

Solves sum_i [L_i - z]_+ = gamma per row by bisection on
[max(L) - gamma, max(L)] — add/compare/halve only (the hardware algorithm,
§III-D / Gu [40]), no sort. Sorting is the natural CPU algorithm but is
expensive on the TPU VPU; bisection with a static trip count vectorizes
across all 8x128 vreg lanes and needs no cross-lane shuffles beyond the
row-sum reduction.

Tiling: grid over row-tiles; each block holds (block_rows, m) in VMEM with
m padded to a multiple of 128 lanes using a large-negative fill (padding
elements then never enter the support set: [(-BIG) - z]_+ == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_FILL = -1e30  # padding value; never enters the support set
DEFAULT_ITERS = 26


def _mp_waterfill_kernel(gamma_ref, L_ref, out_ref, *, iters: int):
    L = L_ref[...]  # (block_rows, m_padded) in VMEM
    gamma = gamma_ref[0, 0]
    hi = jnp.max(L, axis=-1, keepdims=True)   # (br, 1)
    lo = hi - gamma

    def body(_, state):
        lo, hi = state
        mid = (lo + hi) * 0.5  # shift in fixed point
        h = jnp.sum(jnp.maximum(L - mid, 0.0), axis=-1, keepdims=True)
        too_low = h > gamma
        lo = jnp.where(too_low, mid, lo)
        hi = jnp.where(too_low, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    out_ref[...] = (lo + hi) * 0.5


def mp_waterfill_pallas(
    L: jax.Array,
    gamma: jax.Array,
    *,
    iters: int = DEFAULT_ITERS,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """L: (R, m) f32/bf16, gamma: scalar -> z: (R,).

    Rows are tiled into VMEM blocks of (block_rows, m_pad); the full
    reduction axis stays resident (m is the MP operand count — filter taps
    or template count — small by construction in this paper).
    """
    R, m = L.shape
    m_pad = (-m) % 128
    r_pad = (-R) % block_rows
    Lp = jnp.pad(L, ((0, r_pad), (0, m_pad)), constant_values=NEG_FILL)
    Rp, mp_ = Lp.shape
    gamma_arr = jnp.asarray(gamma, dtype=L.dtype).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_mp_waterfill_kernel, iters=iters),
        grid=(Rp // block_rows,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),          # gamma (SMEM-size)
            pl.BlockSpec((block_rows, mp_), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, 1), L.dtype),
        interpret=interpret,
    )(gamma_arr, Lp)
    return out[:R, 0]
