"""Best-known streaming-kernel block shapes (the autotune table).

``benchmarks/kernel_sweep.py`` sweeps slot_block (``block_s``) x chunk
length x session capacity for both stream kernels (float ``fir_mp_stream``
and integer ``fir_mp_stream_q``) and — with ``--update-table`` — persists
the winning ``block_s`` per (kernel, capacity) into the committed
``stream_shapes.json`` next to this module. ``ops.fir_mp_stream`` /
``ops.fir_mp_stream_q`` consult :func:`best_block_s` when the caller does
not pass ``block_s`` explicitly, so a re-run of the sweep on real TPU
hardware retunes the default shapes with a one-line commit and zero call
sites change.

Shape choice never changes VALUES: ``block_s`` only tiles the slot axis
(every slot's math is row-independent), so any entry in this table
preserves the bit-parity contracts. The committed numbers are the
CPU/interpret-mode winners tracked by the benchmark trajectory; they are
placeholders for the real-TPU pass.
"""

from __future__ import annotations

import functools
import json
import os

__all__ = ["best_block_s", "table", "TABLE_PATH", "DEFAULT_BLOCK_S"]

TABLE_PATH = os.path.join(os.path.dirname(__file__), "stream_shapes.json")
DEFAULT_BLOCK_S = 8


@functools.lru_cache(maxsize=1)
def table() -> dict:
    """The committed table: {kernel: {capacity(str): block_s}}. Missing or
    unreadable file -> empty table (defaults apply)."""
    try:
        with open(TABLE_PATH) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def best_block_s(kernel: str, slots: int) -> int:
    """Best-known ``block_s`` for ``kernel`` at session capacity ``slots``:
    the entry for the largest tuned capacity <= ``slots`` (falling back to
    the smallest tuned capacity, then to ``DEFAULT_BLOCK_S``)."""
    entries = table().get(kernel, {})
    caps = sorted(int(c) for c in entries)
    if not caps:
        return DEFAULT_BLOCK_S
    at_or_below = [c for c in caps if c <= slots]
    pick = at_or_below[-1] if at_or_below else caps[0]
    return int(entries[str(pick)])
