"""Public jit'd wrappers around the Pallas MP kernels.

Responsibilities:
  * interpret-mode fallback on CPU (this container) vs compiled on TPU;
  * shape canonicalization (leading batch dims flattened);
  * default block shapes from the committed autotune table
    (``stream_shapes.best_block_s``, refreshed by
    ``benchmarks/kernel_sweep.py --update-table``);
  * a custom VJP for `mp_linear` so the multiplierless layer is trainable
    end-to-end: forward runs the fused Pallas kernel, backward applies the
    water-filling subgradient (support-set masks recomputed from z — the
    same trick as softmax-recompute in flash attention: cheaper to rebuild
    the mask than to store it).

The integer wrappers (``fir_mp_bank_q*``, ``fir_mp_stream_q``) drive the
fixed-point twins. ``fir_mp_stream_q`` is NOT itself jitted: it takes the
compiled ``fixed.FixedPointProgram`` (host-side ROMs and shift tables), so
— exactly like ``fixed.session_step_q`` — callers jit a closure over a
concrete program.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fir_mp as _fir
from repro.kernels import mp_linear as _lin
from repro.kernels import mp_waterfill as _wf
from repro.kernels.stream_shapes import best_block_s


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# mp_waterfill
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("iters",))
def mp_waterfill(L: jax.Array, gamma, *, iters: int = _wf.DEFAULT_ITERS):
    """z = MP(L, gamma) along the last axis; any leading batch shape."""
    lead = L.shape[:-1]
    L2 = L.reshape(-1, L.shape[-1])
    z = _wf.mp_waterfill_pallas(L2, gamma, iters=iters, interpret=_interpret())
    return z.reshape(lead)


# ---------------------------------------------------------------------------
# mp_linear with custom VJP
# ---------------------------------------------------------------------------


def _mp_linear_fwd_impl(x2, w, gamma, iters):
    return _lin.mp_linear_pallas(x2, w, gamma, iters=iters,
                                 interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _mp_linear_core(x2, w, gamma, iters):
    return _mp_linear_fwd_impl(x2, w, gamma, iters)


def _mp_linear_vjp_fwd(x2, w, gamma, iters):
    y = _mp_linear_fwd_impl(x2, w, gamma, iters)
    return y, (x2, w, gamma)


def _mp_linear_vjp_bwd(iters, res, g):
    x2, w, gamma = res
    gamma = jnp.asarray(gamma, x2.dtype)
    # Recompute the two water-fill levels exactly (small: sort over d per
    # (b, o) pair) and form support masks.
    u = x2[:, None, :] + w.T[None, :, :]          # (B, O, d)
    v = x2[:, None, :] - w.T[None, :, :]

    def z_and_masks(t):
        L = jnp.concatenate([t, -t], axis=-1)
        from repro.core.mp import mp_exact
        z = mp_exact(L, gamma)
        s_pos = (t > z[..., None]).astype(x2.dtype)     # d/dt_i of z over +t
        s_neg = (-t > z[..., None]).astype(x2.dtype)    # over -t branch
        k = jnp.maximum(jnp.sum(s_pos + s_neg, -1), 1.0)
        return (s_pos - s_neg) / k[..., None]           # dz/dt_i

    du = z_and_masks(u)       # dz_u/du_i
    dv = z_and_masks(v)       # dz_v/dv_i
    # y = z_u - z_v;  du/dx=+1, du/dw=+1, dv/dx=+1, dv/dw=-1 (v = x - w?) --
    # NOTE: kernel uses u = x + w, v = x - w (see mp_linear kernel).
    gy = g[..., None]                                  # (B, O, 1)
    dx = jnp.sum(gy * (du - dv), axis=1)               # (B, d)
    dw = jnp.sum(gy * (du + dv), axis=0).T             # (d, O)
    # dz/dgamma = -1/k for each solve
    dgamma = jnp.zeros((), x2.dtype)  # gamma non-trained in the kernel path
    return dx, dw, dgamma


_mp_linear_core.defvjp(_mp_linear_vjp_fwd, _mp_linear_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("iters",))
def mp_linear(x: jax.Array, w: jax.Array, gamma,
              *, iters: int = _lin.DEFAULT_ITERS):
    """Multiplierless (..., d) @ (d, O) via the fused Pallas kernel."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _mp_linear_core(x2, w, jnp.asarray(gamma, x.dtype), iters)
    return y.reshape(*lead, w.shape[1])


# ---------------------------------------------------------------------------
# fir_mp
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp(x: jax.Array, h: jax.Array, gamma, *, iters: int = _fir.DEFAULT_ITERS):
    """In-filter MP FIR: x (..., N), h (M,) -> y (..., N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _fir.fir_mp_pallas(x2, h, gamma, iters=iters, interpret=_interpret())
    return y.reshape(*lead, x.shape[-1])


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp_accumulate(x: jax.Array, h: jax.Array, gamma,
                      *, iters: int = _fir.DEFAULT_ITERS):
    """Fused FIR + HWR + accumulate: x (..., N), h (M,) -> s (...)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    s = _fir.fir_mp_pallas(x2, h, gamma, accumulate=True, iters=iters,
                           interpret=_interpret())
    return s.reshape(lead)


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp_bank(x: jax.Array, H: jax.Array, gamma,
                *, iters: int = _fir.DEFAULT_ITERS):
    """Multi-filter in-filter MP FIR: x (..., N), H (F, M) -> y (..., F, N).

    One pallas_call covers the whole bank; the signal block is read from HBM
    once and shared by all F filters (vs F reads with per-filter fir_mp)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _fir.fir_mp_bank_pallas(x2, H, gamma, iters=iters,
                                interpret=_interpret())      # (F, B, N)
    y = jnp.moveaxis(y, 0, 1)                                # (B, F, N)
    return y.reshape(*lead, H.shape[0], x.shape[-1])


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp_bank_accumulate(x: jax.Array, H: jax.Array, gamma,
                           *, iters: int = _fir.DEFAULT_ITERS):
    """Fused bank FIR + HWR + accumulate: x (..., N), H (F, M) -> s (..., F).

    The paper's per-band accumulator readout for a full octave in a single
    kernel invocation: one HBM read of the signal -> F scalar features."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    s = _fir.fir_mp_bank_pallas(x2, H, gamma, accumulate=True, iters=iters,
                                interpret=_interpret())      # (B, F)
    return s.reshape(*lead, H.shape[0])


# ---------------------------------------------------------------------------
# fir_mp_stream: the session-shaped streaming step
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("solver", "update_amax", "block_s"))
def fir_mp_stream(chunk: jax.Array, n: jax.Array, delays: tuple,
                  consumed: tuple, acc: jax.Array, amax: jax.Array,
                  bp_taps: tuple, lp_taps: tuple, gamma, *,
                  solver: str = "newton", update_amax: bool = True,
                  block_s: int | None = None):
    """Stateful multirate session step through the Pallas streaming kernel.

    chunk (S, L): one slot-batched chunk, invalid tails already zeroed (and
    quantized, if deployed quantized — in which case pass the pre-updated
    running amax and ``update_amax=False``; without quantization the octave-0
    kernel updates the running amax in VMEM scratch itself). ``n`` (S,) are
    per-slot valid counts (0 for masked/inert slots), ``delays``/``consumed``
    per-octave register tuples, ``acc`` (S, P) the concatenated per-band
    accumulators, ``bp_taps[o]`` (F, M) / ``lp_taps[o]`` (M_lp,) the
    precomputed filters.

    One pallas_call per octave; each carries that octave's delay line,
    per-band accumulator partials, and (octave 0) running amax in VMEM
    scratch across its chunk-block grid steps — the per-chunk state never
    round-trips through HBM inside the step, and the [delay, chunk] splice
    happens in VMEM rather than as an XLA concatenation. The decimated
    signal hops octaves through HBM exactly once, like the XLA path's
    octave cascade.

    Returns ``(delays', consumed', acc', amax')``. Masked slots (n == 0)
    are inert: their registers come back bit-identical (delay slides by 0,
    accumulator contributions are exactly +0.0). ``block_s=None`` (default)
    consults the committed autotune table (``stream_shapes``) for the
    best-known slot tile at this capacity — shape choice never changes
    values, only VMEM tiling.
    """
    num_octaves = len(delays)
    S, L = chunk.shape
    if block_s is None:
        block_s = best_block_s("fir_mp_stream", S)
    F = bp_taps[0].shape[0]
    x_o = chunk
    n_o = jnp.asarray(n, jnp.int32)
    l_o = L
    new_delays, new_consumed, acc_cols = [], [], []
    amax_out = amax
    interpret = _interpret()
    for o in range(num_octaves):
        start_o = jnp.remainder(consumed[o], 2).astype(jnp.int32)
        emit = o < num_octaves - 1
        lp = lp_taps[o] if emit else jnp.zeros((1,), chunk.dtype)
        acc_o = jax.lax.slice_in_dim(acc, o * F, (o + 1) * F, axis=1)
        amax_in = amax if o == 0 else jnp.zeros((S,), chunk.dtype)
        acc_new, delay_new, amax_new, y_next = _fir.fir_mp_stream_octave(
            x_o, n_o, start_o, delays[o], acc_o, amax_in, bp_taps[o], lp,
            gamma, scale=2.0 ** o, solver=solver, emit_next=emit,
            update_amax=(update_amax and o == 0), block_s=block_s,
            interpret=interpret)
        if o == 0:
            amax_out = amax_new if update_amax else amax
        new_delays.append(delay_new)
        new_consumed.append(consumed[o] + n_o)
        acc_cols.append(acc_new)
        if emit:
            l_next = (l_o + 1) // 2
            x_o = y_next[:, :l_next]
            n_o = jnp.maximum(0, (n_o - start_o + 1) // 2)
            l_o = l_next
    return (tuple(new_delays), tuple(new_consumed),
            jnp.concatenate(acc_cols, axis=1), amax_out)


# ---------------------------------------------------------------------------
# integer (fixed-point) wrappers: the VMEM-resident hardware twin
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("gamma_q", "iters", "qmin", "qmax"))
def fir_mp_bank_q(xq: jax.Array, H_q: jax.Array, *, gamma_q: int,
                  iters: int, qmin: int, qmax: int):
    """Integer bank FIR through the fused Pallas kernel: xq (..., N) signal
    codes already on the stage's internal grid, H_q (F, M) tap codes ->
    (..., F, N) band codes, bit-for-bit ``fixed.fxp_fir_bank(pad=True)``.
    ``gamma_q``/``iters``/``qmin``/``qmax`` are static program constants."""
    lead = xq.shape[:-1]
    x2 = xq.reshape(-1, xq.shape[-1])
    y = _fir.fir_mp_bank_q_pallas(x2, H_q, gamma_q=gamma_q, iters=iters,
                                  qmin=qmin, qmax=qmax,
                                  interpret=_interpret())      # (F, B, N)
    y = jnp.moveaxis(y, 0, 1)                                  # (B, F, N)
    return y.reshape(*lead, H_q.shape[0], xq.shape[-1])


@functools.partial(jax.jit,
                   static_argnames=("gamma_q", "iters", "qmin", "qmax"))
def fir_mp_bank_q_accumulate(xq: jax.Array, H_q: jax.Array, *, gamma_q: int,
                             iters: int, qmin: int, qmax: int):
    """Fused integer bank FIR + HWR + accumulate: xq (..., N) -> (..., F)
    integer sums at the stage grid (the caller applies ``acc_shift``).
    One HBM read of the signal codes serves the whole octave's filter set
    AND the paper's per-band accumulator readout."""
    lead = xq.shape[:-1]
    x2 = xq.reshape(-1, xq.shape[-1])
    s = _fir.fir_mp_bank_q_pallas(x2, H_q, gamma_q=gamma_q, iters=iters,
                                  qmin=qmin, qmax=qmax, accumulate=True,
                                  interpret=_interpret())      # (B, F)
    return s.reshape(*lead, H_q.shape[0])


def fir_mp_stream_q(prog, chunk_q: jax.Array, n: jax.Array, delays: tuple,
                    consumed: tuple, acc: jax.Array, amax: jax.Array, *,
                    block_s: int | None = None):
    """Stateful INTEGER multirate session step through the Pallas kernels:
    the VMEM-resident twin of ``fixed.session_step_q``'s octave cascade.

    ``prog`` is the compiled ``fixed.FixedPointProgram`` (static ROMs/shift
    tables — which is why this wrapper is not itself jitted: jit a closure
    over a concrete program, exactly like ``session_step_q``). ``chunk_q``
    (S, L) is ADC codes with invalid tails already zeroed; ``n`` (S,)
    effective valid counts; ``delays``/``consumed``/``acc``/``amax`` the
    integer session registers. Requires mode "mp" and L >= 1 (the caller
    handles the L == 0 pure-readout step).

    One pallas_call per octave, same state machine as the float
    ``fir_mp_stream``; every in-kernel op is shift/add/compare, and the
    result registers are bit-for-bit ``session_step_q``'s (and therefore
    bit-for-bit one-shot ``infer_q`` under any chunking — the fixed-grid
    exactness argument in docs/numerics.md). Returns
    ``(delays', consumed', acc', amax')``.
    """
    bank = prog.bank
    if bank.mode != "mp":
        raise ValueError(
            f"fir_mp_stream_q runs the MP streaming kernel; it has no "
            f"{bank.mode!r}-mode variant (use fixed.session_step_q)")
    S, L = chunk_q.shape
    if block_s is None:
        block_s = best_block_s("fir_mp_stream_q", S)
    x_o = chunk_q
    n_o = jnp.asarray(n, jnp.int32)
    l_o = L
    new_delays, new_consumed, acc_cols = [], [], []
    amax_out = amax
    interpret = _interpret()
    col = 0
    for o, st in enumerate(bank.octaves):
        F = st.bp_q.shape[0]
        emit = st.lp_q is not None
        # parity phase by bit-AND, not remainder: the census stays
        # divider-free (mirrors session_step_q)
        start_o = jnp.bitwise_and(consumed[o], 1).astype(jnp.int32)
        acc_o = jax.lax.slice_in_dim(acc, col, col + F, axis=1)
        amax_in = amax if o == 0 else jnp.zeros((S,), chunk_q.dtype)
        next_spec = bank.octaves[o + 1].in_spec if emit else None
        acc_new, delay_new, amax_new, y_next = _fir.fir_mp_stream_octave_q(
            x_o, n_o, start_o, delays[o], acc_o, amax_in, stage=st,
            next_spec=next_spec, emit_next=emit, update_amax=(o == 0),
            block_s=block_s, interpret=interpret)
        if o == 0:
            amax_out = amax_new
        new_delays.append(delay_new)
        new_consumed.append(consumed[o] + n_o)
        acc_cols.append(acc_new)
        col += F
        if emit:
            l_next = (l_o + 1) // 2
            x_o = y_next[:, :l_next]
            # kept-count update: arithmetic shift, not an integer divide
            n_o = jnp.right_shift(jnp.maximum(n_o - start_o + 1, 0), 1)
            l_o = l_next
    return (tuple(new_delays), tuple(new_consumed),
            jnp.concatenate(acc_cols, axis=1), amax_out)
