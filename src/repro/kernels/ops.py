"""Public jit'd wrappers around the Pallas MP kernels.

Responsibilities:
  * interpret-mode fallback on CPU (this container) vs compiled on TPU;
  * shape canonicalization (leading batch dims flattened);
  * a custom VJP for `mp_linear` so the multiplierless layer is trainable
    end-to-end: forward runs the fused Pallas kernel, backward applies the
    water-filling subgradient (support-set masks recomputed from z — the
    same trick as softmax-recompute in flash attention: cheaper to rebuild
    the mask than to store it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fir_mp as _fir
from repro.kernels import mp_linear as _lin
from repro.kernels import mp_waterfill as _wf


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# mp_waterfill
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("iters",))
def mp_waterfill(L: jax.Array, gamma, *, iters: int = _wf.DEFAULT_ITERS):
    """z = MP(L, gamma) along the last axis; any leading batch shape."""
    lead = L.shape[:-1]
    L2 = L.reshape(-1, L.shape[-1])
    z = _wf.mp_waterfill_pallas(L2, gamma, iters=iters, interpret=_interpret())
    return z.reshape(lead)


# ---------------------------------------------------------------------------
# mp_linear with custom VJP
# ---------------------------------------------------------------------------


def _mp_linear_fwd_impl(x2, w, gamma, iters):
    return _lin.mp_linear_pallas(x2, w, gamma, iters=iters,
                                 interpret=_interpret())


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _mp_linear_core(x2, w, gamma, iters):
    return _mp_linear_fwd_impl(x2, w, gamma, iters)


def _mp_linear_vjp_fwd(x2, w, gamma, iters):
    y = _mp_linear_fwd_impl(x2, w, gamma, iters)
    return y, (x2, w, gamma)


def _mp_linear_vjp_bwd(iters, res, g):
    x2, w, gamma = res
    gamma = jnp.asarray(gamma, x2.dtype)
    # Recompute the two water-fill levels exactly (small: sort over d per
    # (b, o) pair) and form support masks.
    u = x2[:, None, :] + w.T[None, :, :]          # (B, O, d)
    v = x2[:, None, :] - w.T[None, :, :]

    def z_and_masks(t):
        L = jnp.concatenate([t, -t], axis=-1)
        from repro.core.mp import mp_exact
        z = mp_exact(L, gamma)
        s_pos = (t > z[..., None]).astype(x2.dtype)     # d/dt_i of z over +t
        s_neg = (-t > z[..., None]).astype(x2.dtype)    # over -t branch
        k = jnp.maximum(jnp.sum(s_pos + s_neg, -1), 1.0)
        return (s_pos - s_neg) / k[..., None]           # dz/dt_i

    du = z_and_masks(u)       # dz_u/du_i
    dv = z_and_masks(v)       # dz_v/dv_i
    # y = z_u - z_v;  du/dx=+1, du/dw=+1, dv/dx=+1, dv/dw=-1 (v = x - w?) --
    # NOTE: kernel uses u = x + w, v = x - w (see mp_linear kernel).
    gy = g[..., None]                                  # (B, O, 1)
    dx = jnp.sum(gy * (du - dv), axis=1)               # (B, d)
    dw = jnp.sum(gy * (du + dv), axis=0).T             # (d, O)
    # dz/dgamma = -1/k for each solve
    dgamma = jnp.zeros((), x2.dtype)  # gamma non-trained in the kernel path
    return dx, dw, dgamma


_mp_linear_core.defvjp(_mp_linear_vjp_fwd, _mp_linear_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("iters",))
def mp_linear(x: jax.Array, w: jax.Array, gamma,
              *, iters: int = _lin.DEFAULT_ITERS):
    """Multiplierless (..., d) @ (d, O) via the fused Pallas kernel."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _mp_linear_core(x2, w, jnp.asarray(gamma, x.dtype), iters)
    return y.reshape(*lead, w.shape[1])


# ---------------------------------------------------------------------------
# fir_mp
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp(x: jax.Array, h: jax.Array, gamma, *, iters: int = _fir.DEFAULT_ITERS):
    """In-filter MP FIR: x (..., N), h (M,) -> y (..., N)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _fir.fir_mp_pallas(x2, h, gamma, iters=iters, interpret=_interpret())
    return y.reshape(*lead, x.shape[-1])


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp_accumulate(x: jax.Array, h: jax.Array, gamma,
                      *, iters: int = _fir.DEFAULT_ITERS):
    """Fused FIR + HWR + accumulate: x (..., N), h (M,) -> s (...)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    s = _fir.fir_mp_pallas(x2, h, gamma, accumulate=True, iters=iters,
                           interpret=_interpret())
    return s.reshape(lead)


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp_bank(x: jax.Array, H: jax.Array, gamma,
                *, iters: int = _fir.DEFAULT_ITERS):
    """Multi-filter in-filter MP FIR: x (..., N), H (F, M) -> y (..., F, N).

    One pallas_call covers the whole bank; the signal block is read from HBM
    once and shared by all F filters (vs F reads with per-filter fir_mp)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _fir.fir_mp_bank_pallas(x2, H, gamma, iters=iters,
                                interpret=_interpret())      # (F, B, N)
    y = jnp.moveaxis(y, 0, 1)                                # (B, F, N)
    return y.reshape(*lead, H.shape[0], x.shape[-1])


@functools.partial(jax.jit, static_argnames=("iters",))
def fir_mp_bank_accumulate(x: jax.Array, H: jax.Array, gamma,
                           *, iters: int = _fir.DEFAULT_ITERS):
    """Fused bank FIR + HWR + accumulate: x (..., N), H (F, M) -> s (..., F).

    The paper's per-band accumulator readout for a full octave in a single
    kernel invocation: one HBM read of the signal -> F scalar features."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    s = _fir.fir_mp_bank_pallas(x2, H, gamma, accumulate=True, iters=iters,
                                interpret=_interpret())      # (B, F)
    return s.reshape(*lead, H.shape[0])
