"""Pallas TPU kernels for the MP (Margin Propagation) hot spots.

Each kernel ships three layers:
  <name>.py  - pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ops.py     - jit'd public wrappers (padding, interpret-mode fallback, vjp)
  ref.py     - pure-jnp oracles the tests assert against

Kernels:
  mp_waterfill - row-wise reverse water-filling z = MP(L, gamma) by bisection
  mp_linear    - fused multiplierless MVM: y = mpabs(w+x) - mpabs(w-x)
  fir_mp       - in-filter MP FIR: sliding windows formed in VMEM (no HBM
                 window matrix), both MP states solved in one pass, optional
                 fused HWR+accumulate (the paper's s_p readout)
  fir_mp_bank  - multi-filter fir_mp: grid (batch_tile, filter) with the
                 filter axis innermost so one VMEM-resident signal block
                 serves a whole octave's filter set in a single pallas_call
  fir_mp_stream - stateful session-step kernel: grid (slot, chunk_block,
                 filter) carrying each slot's FIR delay line, per-band
                 accumulators and running amax in VMEM scratch across grid
                 steps (the step()-shaped streaming hot path; bit-identical
                 to the XLA session step in interpret mode)
  fir_mp_bank_q / fir_mp_stream_q - the INTEGER twins of the two fused
                 kernels: the bit-true fixed-point datapath (integer MP
                 bisection, shift/add/compare only) on the same grids,
                 bit-for-bit equal to the fxp_* XLA kernels and censused
                 multiplier-free by benchmarks/hardware_cost.py

Default block shapes come from the committed autotune table
(stream_shapes.json, refreshed by benchmarks/kernel_sweep.py).
"""

from repro.kernels.ops import (  # noqa: F401
    mp_waterfill,
    mp_linear,
    fir_mp,
    fir_mp_accumulate,
    fir_mp_bank,
    fir_mp_bank_accumulate,
    fir_mp_bank_q,
    fir_mp_bank_q_accumulate,
    fir_mp_stream,
    fir_mp_stream_q,
)
